"""One benchmark per paper table/figure (§5), on the calibrated simulator.

All strategy×method variants are produced by iterating the
:mod:`repro.core.engine` strategy registry — no hand-stitched matrices.

Figure 4a  — homogeneous expansion times (MN5, 112-core nodes)
Figure 4b  — homogeneous shrink times (TS vs B-based)
Figure 5   — preferred-method grid
Figure 6a/b — heterogeneous expansion/shrink (NASP, 20/32-core nodes)
Table 2    — iterative diffusive worked example
Figure 1 / Eq. 3 — hypercube round counts
Scenarios  — the declarative workload traces, timeline-charged
Redistribution — stage-3 bytes-moved sweep over model configs
Overlap    — partial-overlap (fraction x contention) downtime sweep
Policy sweep — strategy x RMS-policy trace makespan/downtime envelopes
Faults     — checkpoint/restart traces (ckpt bytes, restored bytes)
Serve      — strategy x traffic-trace latency percentiles (elastic decode)
Scheduler  — closed-loop knob search vs the rigid-cluster baseline,
             winning knobs replayed under every spawning strategy

The expensive table functions take their grids as parameters so the
``--smoke`` mode of ``run.py`` can shrink them without touching the
table logic (the cheap scenario/policy tables always run in full).
"""
from __future__ import annotations

import itertools
import time

# Everything below comes off the stable surface (docs/api.md) — the
# benchmark suite is user code and programs against repro.api only.
from repro.api import (
    FAULT_SCENARIO_NAMES,
    KNOB_GRID,
    MN5,
    NASP,
    POLICY_SCENARIO_NAMES,
    SERVE_SCENARIO_NAMES,
    WORKLOAD_TRACES,
    ChurnPolicy,
    ThroughputModel,
    ClusterState as RmsClusterState,
    JobSpec,
    Method,
    ReconfigEngine,
    SchedulerKnobs,
    ShrinkKind,
    Stage,
    Strategy,
    StrategySpec,
    churn_trace,
    evaluate_schedule,
    fsdp_bytes_model,
    get_scenario,
    monte_carlo_sweep,
    optimize_schedule,
    param_bytes_for_arch,
    plan_diffusive,
    plan_hypercube,
    registered_scenarios,
    registered_strategies,
    replicated_bytes_model,
    run_scenario_sim,
    run_scenario_vectorized,
    time_to_result,
    run_serve,
    running_vector,
    shrink_timeline,
    simulate_expansion,
    simulate_shrink,
)

MN5_CORES = 112
MN5_NODES = [1, 2, 4, 8, 16, 24, 32]
NASP_NODES = [1, 2, 4, 6, 8, 10, 12, 14, 16]


def nasp_alloc(n: int) -> list[int]:
    """Balanced heterogeneous allocation: alternating 20/32-core nodes
    (one node -> the 20-core type, per §5.3)."""
    return [20 if i % 2 == 0 else 32 for i in range(n)]


def variant_label(spec: StrategySpec, method: Method) -> str:
    """Paper-facing variant names: M / B / M+hypercube / B+diffusive / ..."""
    m = "M" if method is Method.MERGE else "B"
    if spec.key == Strategy.SEQUENTIAL.value:
        return m
    return f"{m}+{spec.key}"


def expansion_variants(ns, nt, cores, cm, *, parallel_only=False,
                       include_baseline=False,
                       methods=(Method.MERGE, Method.BASELINE)):
    """(label, ExpansionReport) for every applicable registered strategy.

    ``include_baseline`` re-adds the sequential-Merge "M" row (the paper's
    normalization baseline) when ``parallel_only`` would filter it out.
    """
    engine = ReconfigEngine(cost_model=cm)
    out = []
    for spec in registered_strategies():
        if parallel_only and not spec.parallel:
            if include_baseline and spec.key == Strategy.SEQUENTIAL.value:
                plan = engine.plan_expand(
                    ns, nt, cores, strategy=spec.key, method=Method.MERGE)
                out.append(("M", simulate_expansion(plan.spawn, cm)))
            continue
        if spec.homogeneous_only and not isinstance(cores, int):
            widths = set(cores)
            if len(widths) != 1:
                continue
        for method in methods:
            plan = engine.plan_expand(
                ns, nt, cores, strategy=spec.key, method=method)
            out.append((variant_label(spec, method),
                        simulate_expansion(plan.spawn, cm)))
    return out


# ------------------------------------------------------ Fig 4a: expansion --
def fig4a_homogeneous_expansion(nodes: list[int] = MN5_NODES) -> list[dict]:
    rows = []
    for i, n in itertools.combinations(nodes, 2):
        ns, nt = i * MN5_CORES, n * MN5_CORES
        variants = dict(expansion_variants(
            ns, nt, MN5_CORES, MN5, parallel_only=True, include_baseline=True))
        base = variants["M"].total
        for name, rep in variants.items():
            rows.append({
                "figure": "4a", "I": i, "N": n, "method": name,
                "time_s": round(rep.total, 4),
                "vs_merge": round(rep.total / base, 3),
            })
    return rows


# -------------------------------------------------------- Fig 4b: shrink --
def fig4b_homogeneous_shrink(nodes: list[int] = MN5_NODES) -> list[dict]:
    rows = []
    for n, i in itertools.combinations(nodes, 2):  # i -> n, i > n
        ns, nt = i * MN5_CORES, n * MN5_CORES
        ts = simulate_shrink(
            ShrinkKind.TS, MN5, ns=ns, nt=nt,
            doomed_world_sizes=[MN5_CORES] * (i - n),
        ).total
        for name, method in [("B+hypercube", Method.BASELINE)]:
            rp = plan_hypercube(ns, nt, MN5_CORES, method)
            ss = simulate_shrink(ShrinkKind.SS, MN5, ns=ns, nt=nt, respawn_plan=rp).total
            rows.append({
                "figure": "4b", "I": i, "N": n, "method": name,
                "time_s": round(ss, 4), "speedup_ts": round(ss / ts, 1),
            })
        rows.append({
            "figure": "4b", "I": i, "N": n, "method": "M+TS",
            "time_s": round(ts, 6), "speedup_ts": 1.0,
        })
    return rows


# ------------------------------------------------ Fig 5: preferred method --
def fig5_preferred_grid(nodes: list[int] = MN5_NODES) -> list[dict]:
    """Best method per (I, N) cell: expansion upper triangle, shrink lower.

    Expansion candidates come from the full strategy registry (classic
    strategies included: they never win, which is the paper's point)."""
    rows = []
    for i in nodes:
        for n in nodes:
            if i == n:
                continue
            ns, nt = i * MN5_CORES, n * MN5_CORES
            if n > i:   # expansion
                cand = {
                    label: rep.total
                    for label, rep in expansion_variants(ns, nt, MN5_CORES, MN5)
                }
            else:       # shrink
                cand = {
                    "M+TS": simulate_shrink(
                        ShrinkKind.TS, MN5, ns=ns, nt=nt,
                        doomed_world_sizes=[MN5_CORES] * (i - n)).total,
                    "B+par": simulate_shrink(
                        ShrinkKind.SS, MN5, ns=ns, nt=nt,
                        respawn_plan=plan_hypercube(ns, nt, MN5_CORES, Method.BASELINE),
                    ).total,
                }
            best = min(cand, key=cand.get)
            rows.append({"figure": "5", "I": i, "N": n, "best": best,
                         "time_s": round(cand[best], 5)})
    return rows


# --------------------------------------- Fig 6: heterogeneous (diffusive) --
def fig6_heterogeneous(nodes: list[int] = NASP_NODES) -> list[dict]:
    rows = []
    for i, n in itertools.combinations(nodes, 2):
        alloc = nasp_alloc(n)
        ns, nt = sum(nasp_alloc(i)), sum(alloc)
        variants = dict(expansion_variants(
            ns, nt, alloc, NASP, parallel_only=True, include_baseline=True))
        base = variants["M"].total
        for name, rep in variants.items():
            rows.append({"figure": "6a", "I": i, "N": n, "method": name,
                         "time_s": round(rep.total, 4),
                         "vs_merge": round(rep.total / base, 3)})
    for n, i in itertools.combinations(nodes, 2):
        alloc_t = nasp_alloc(n)
        ns, nt = sum(nasp_alloc(i)), sum(alloc_t)
        doomed = nasp_alloc(i)[n:]
        ts = simulate_shrink(ShrinkKind.TS, NASP, ns=ns, nt=nt,
                             doomed_world_sizes=doomed).total
        rp = plan_diffusive(alloc_t, running_vector(alloc_t, min(ns, nt)),
                            Method.BASELINE)
        ss = simulate_shrink(ShrinkKind.SS, NASP, ns=ns, nt=nt, respawn_plan=rp).total
        rows.append({"figure": "6b", "I": i, "N": n, "method": "B+diffusive",
                     "time_s": round(ss, 4), "speedup_ts": round(ss / ts, 1)})
        rows.append({"figure": "6b", "I": i, "N": n, "method": "M+TS",
                     "time_s": round(ts, 6), "speedup_ts": 1.0})
    return rows


# ------------------------------------------------- Table 2 + Eq. 3 traces --
def table2_trace() -> list[dict]:
    A = [4, 2, 8, 12, 3, 3, 4, 4, 6, 3]
    R = [2, 0, 0, 0, 0, 0, 0, 0, 0, 0]
    plan = plan_diffusive(A, R, Method.MERGE)
    return [
        {"figure": "T2", "s": tr.s, "t": tr.t, "g": tr.g, "lambda": tr.lam,
         "T": tr.T, "G": tr.G}
        for tr in plan.trace
    ]


def fig1_hypercube_rounds() -> list[dict]:
    rows = []
    for cores, i, n in [(1, 1, 8), (20, 1, 21), (20, 1, 441), (112, 1, 32),
                        (112, 2, 32), (112, 16, 32)]:
        plan = plan_hypercube(i * cores, n * cores, cores, Method.MERGE)
        rows.append({"figure": "1/Eq3", "C": cores, "I": i, "N": n,
                     "rounds": plan.steps, "groups": len(plan.groups)})
    return rows


# --------------------------------------------------- declarative scenarios --
def scenario_traces(scenarios=None) -> list[dict]:
    """Every registered scenario, timeline-charged by the engine."""
    rows = []
    for sc in scenarios if scenarios is not None else registered_scenarios():
        for rec in run_scenario_sim(sc):
            rows.append({
                "scenario": sc.name, "step": rec.step, "kind": rec.kind,
                "mechanism": rec.mechanism,
                "nodes": f"{rec.nodes_before}->{rec.nodes_after}",
                "time_s": round(rec.est_wall_s, 6),
                "downtime_s": round(rec.downtime_s, 6),
                "bytes_moved": rec.bytes_moved,
                "bytes_stayed": rec.bytes_stayed,
            })
    return rows


# ---------------------------------------- heterogeneous strategy traces --
HETERO_TRACES = ("hetero-nasp", "hetero-redist")


def table_hetero_strategies(traces: tuple[str, ...] = HETERO_TRACES) -> list[dict]:
    """Diffusive vs classic strategies on the uneven-width traces (§5.3).

    Every vector-capable registered strategy replays each heterogeneous
    trace through the simulator (hypercube is homogeneous-only and
    skipped); the diffusive rows are the paper's point — log-depth
    spawn rounds beat the serial classics as the uneven pool grows,
    while TS shrinks stay free of spawning for every strategy.  The
    ``hetero-redist`` rows additionally carry per-link stage-3 bytes
    (stayed charged on the local link, moved on the cross link).
    """
    rows = []
    for name in traces:
        sc = get_scenario(name)
        for spec in registered_strategies():
            if spec.homogeneous_only and sc.heterogeneous:
                continue
            recs = run_scenario_sim(
                sc, engine=sc.default_engine(strategy=spec.key))
            rows.append({
                "scenario": name,
                "strategy": spec.key,
                "events": len(recs),
                "makespan_s": round(sum(r.est_wall_s for r in recs), 6),
                "downtime_s": round(sum(r.downtime_s for r in recs), 6),
                "bytes_moved": sum(r.bytes_moved for r in recs),
                "bytes_stayed": sum(r.bytes_stayed for r in recs),
            })
    return rows


# -------------------------------------------- topology-aware placement --
TOPO_TRACES = ("topo-nasp", "topo-redist", "topo-pods")


def table_topology(traces: tuple[str, ...] = TOPO_TRACES) -> list[dict]:
    """Topo vs diffusive vs classics on the rack-topology traces.

    Every vector-capable registered strategy replays each topology-aware
    trace through the simulator (all of them price stage-3 bytes per
    distance class — the rack tree rides on the engine); only ``topo``
    also *places* against it: rack-local regrows and rack-vacating
    shrinks, which is what moves bytes off the cross_rack link.  The
    per-class byte columns are the table's point: on ``topo-redist`` the
    greedy classics leave the vacated rack fragmented and keep paying
    cross-rack bandwidth where topo pays intra-rack.
    """
    rows = []
    for name in traces:
        sc = get_scenario(name)
        for spec in registered_strategies():
            if spec.homogeneous_only and sc.heterogeneous:
                continue
            recs = run_scenario_sim(
                sc, engine=sc.default_engine(strategy=spec.key))
            by_class = {"intra_node": 0, "intra_rack": 0,
                        "cross_rack": 0, "cross_pod": 0}
            for rec in recs:
                for cls, b in rec.bytes_by_class.items():
                    by_class[cls] += b
            rows.append({
                "scenario": name,
                "strategy": spec.key,
                "events": len(recs),
                "makespan_s": round(sum(r.est_wall_s for r in recs), 6),
                "downtime_s": round(sum(r.downtime_s for r in recs), 6),
                "bytes_intra_node": by_class["intra_node"],
                "bytes_intra_rack": by_class["intra_rack"],
                "bytes_cross_rack": by_class["cross_rack"],
                "bytes_cross_pod": by_class["cross_pod"],
            })
    return rows


# ------------------------------------------------ RMS policy x strategy --
def policy_sweep(traces: tuple[str, ...] = POLICY_SCENARIO_NAMES) -> list[dict]:
    """Makespan/downtime/bytes envelopes: strategy x RMS-policy trace.

    Each policy-generated trace (backfill pressure, priority preemption,
    seeded churn, two-job interference) replayed under EVERY registered
    spawning strategy: the cumulative reconfiguration makespan is where
    the policy layer's grow/shrink pattern meets the mechanism's cost.
    QUEUE spans (arbitration waits) count toward makespan, never
    downtime, so the queued column separates scheduling delay from
    mechanism stall.  Those spans are part of the declarative trace —
    priced once, by the policy's default (hypercube/MERGE) engine, when
    the trace was generated — so the queued column is constant across
    strategy rows by design: the sweep varies the mechanism under an
    identical schedule, it does not re-run the policy.
    """
    rows = []
    for trace in traces:
        sc = get_scenario(trace)
        for spec in registered_strategies():
            if spec.homogeneous_only and sc.heterogeneous:
                continue
            recs = run_scenario_sim(sc, engine=sc.default_engine(strategy=spec.key))
            rows.append({
                "policy": trace,
                "strategy": spec.key,
                "events": len(recs),
                "makespan_s": round(sum(r.est_wall_s for r in recs), 6),
                "downtime_s": round(sum(r.downtime_s for r in recs), 6),
                "queued_s": round(sum(r.queued_s for r in recs), 6),
                "bytes_moved": sum(r.bytes_moved for r in recs),
            })
    return rows


# ---------------------------------------------- fault-tolerance traces --
def table_faults(traces: tuple[str, ...] = FAULT_SCENARIO_NAMES) -> list[dict]:
    """Checkpoint/restart traces under EVERY registered spawning strategy.

    The three fault scenarios exercise the full-stop path next to the
    malleable one: ``ckpt-cycle`` prices periodic CHECKPOINT snapshots,
    ``node-fail-wave`` charges the doomed ranks' restored shards on every
    failure wave (RESTORE rides the recovery shrink), and
    ``restart-vs-shrink`` puts a rigid SS restart and a malleable TS
    shrink of the same allocation drop side by side.  The byte columns
    are the story: checkpointed/restored bytes are strategy-independent
    (the snapshot is priced by the checkpoint link, not the spawn
    mechanism), while the makespan spread across strategies is exactly
    the respawn cost the restart path re-pays and the shrink path never
    does.
    """
    rows = []
    for name in traces:
        sc = get_scenario(name)
        for spec in registered_strategies():
            if spec.homogeneous_only and sc.heterogeneous:
                continue
            recs = run_scenario_sim(
                sc, engine=sc.default_engine(strategy=spec.key))
            rows.append({
                "scenario": name,
                "strategy": spec.key,
                "events": len(recs),
                "makespan_s": round(sum(r.est_wall_s for r in recs), 6),
                "downtime_s": round(sum(r.downtime_s for r in recs), 6),
                "restored_s": round(sum(r.restored_s for r in recs), 6),
                "bytes_checkpointed": sum(r.bytes_checkpointed for r in recs),
                "bytes_restored": sum(r.bytes_restored for r in recs),
                "bytes_moved": sum(r.bytes_moved for r in recs),
            })
    return rows


# ------------------------------------------------ elastic serving plane --
def table_serve(traces: tuple[str, ...] = SERVE_SCENARIO_NAMES) -> list[dict]:
    """Traffic-policy traces through the elastic decode service (§4/§5).

    Each registered serve traffic trace (diurnal load, flash crowd, SLO
    breach with queued grants) replayed end-to-end — paged KV caches
    migrated on every resize, requests never dropped — under EVERY
    registered spawning strategy.  Request latency percentiles are where
    reconfiguration downtime meets the request stream: the p99 column
    carries the resize stalls, the cross-rack byte column shows what the
    flash-crowd burst grow pays off-rack.  All numbers are deterministic
    simulator output, so they drift-gate like any other table.
    """
    rows = []
    for name in traces:
        sc = get_scenario(name)
        for spec in registered_strategies():
            if spec.homogeneous_only and sc.heterogeneous:
                continue
            rep = run_serve(name, strategy=spec.key)
            rows.append({
                "scenario": name,
                "strategy": spec.key,
                "resizes": len(rep.records),
                "completed": rep.completed,
                "p50_latency_s": round(rep.p50_latency_s, 6),
                "p99_latency_s": round(rep.p99_latency_s, 6),
                "downtime_s": round(rep.downtime_s, 6),
                "queued_s": round(rep.queued_s, 6),
                "bytes_moved": rep.bytes_moved,
                "bytes_cross_rack": rep.bytes_cross_rack,
            })
    return rows


# ------------------------------------------ closed-loop scheduler search --
# --smoke subset of the knob grid: 8 corners instead of 27 cells (plus
# fewer random restarts), same search code path.
SCHED_SMOKE_GRID = tuple(
    SchedulerKnobs(backfill_threshold=t, preempt_priority=p,
                   placement_quantum=q)
    for t in (1, 4) for p in (80, 1000) for q in (1, 2)
)
SCHED_SMOKE_RANDOM = 2
SCHED_FULL_RANDOM = 8


def table_scheduler(grid=None, n_random: int = SCHED_FULL_RANDOM,
                    seed: int = 0) -> list[dict]:
    """Closed-loop scheduler optimizer vs the rigid-cluster control.

    For every registered SLURM-scale workload trace
    (:data:`repro.api.WORKLOAD_TRACES`), run the seeded knob search once
    under the workload's default strategy, then re-evaluate the winning
    knobs under EVERY registered spawning strategy — one schedule, many
    mechanisms, so the strategy rows are apples-to-apples.  The
    ``rigid-baseline`` row is the control a rigid cluster gives you:
    malleables pinned at peak request, zero reconfiguration cost, queue
    and idle time paying for it.  ``beats_baseline`` in every strategy
    row's derived column is the acceptance criterion: the optimized
    malleable schedule must score better than rigid for every workload
    under every mechanism.  The ``expand_downtime`` column is where
    ``dmr-async``'s two-phase overlap shows up against the synchronous
    strategies on the identical schedule.
    """
    rows = []
    for name, trace in sorted(WORKLOAD_TRACES.items()):
        result = optimize_schedule(
            trace, grid=grid if grid is not None else KNOB_GRID,
            n_random=n_random, seed=seed)
        knobs = result.best.knobs
        base = result.baseline
        rows.append({
            "workload": name, "strategy": "rigid-baseline",
            "score": round(base.score, 6),
            "makespan_s": round(base.makespan_s, 6),
            "downtime_s": round(base.downtime_s, 6),
            "expand_downtime_s": round(base.expand_downtime_s, 6),
            "mean_queue_s": round(base.mean_queue_s, 6),
            "utilization": round(base.utilization, 4),
            "reconfigs": base.reconfigs,
            "beats_baseline": False,
        })
        for spec in registered_strategies():
            out = evaluate_schedule(trace, knobs, strategy=spec.key)
            rows.append({
                "workload": name, "strategy": spec.key,
                "score": round(out.score, 6),
                "makespan_s": round(out.makespan_s, 6),
                "downtime_s": round(out.downtime_s, 6),
                "expand_downtime_s": round(out.expand_downtime_s, 6),
                "mean_queue_s": round(out.mean_queue_s, 6),
                "utilization": round(out.utilization, 4),
                "reconfigs": out.reconfigs,
                "beats_baseline": out.score < base.score,
            })
    return rows


# ---------------------------------------------- throughput-coupled cost --
#: Frozen device-free constants for the throughput rows: a 250M-param
#: fp32 model (``flops_per_token = 6 x params``, ``param_bytes =
#: 4 x params``) at the default train_4k shape.  Big enough that the
#: allocation's width moves the modeled step time, small enough that
#: reconfiguration cost still matters — the regime where the makespan
#: and time-to-result objectives genuinely disagree.
THRPT_MODEL = ThroughputModel(flops_per_token=1.5e9, param_bytes=10**9)
#: The optimizer's uneven pool: four wide nodes fronting a long tail of
#: single-chip hosts.  The workload traces declare no ``core_pool`` of
#: their own, so the model pins the widths.
THRPT_POOL = (4, 4, 2, 2) + (1,) * 28
THRPT_MODEL_UNEVEN = ThroughputModel(
    flops_per_token=1.5e9, param_bytes=10**9, node_widths=THRPT_POOL)
#: One even trace, one uneven-width trace — the per-strategy contrast.
THRPT_TRACES = ("steady-cycle", "hetero-nasp")


def table_throughput(traces: tuple[str, ...] = THRPT_TRACES, grid=None,
                     n_random: int = SCHED_FULL_RANDOM,
                     seed: int = 0) -> list[dict]:
    """Modeled time-to-result: per-strategy traces + the objective swap.

    Strategy rows replay an even (``steady-cycle``) and an uneven-width
    (``hetero-nasp``) trace under every capable strategy with
    :data:`THRPT_MODEL` accrued into the records, then price the full
    horizon with :func:`repro.api.time_to_result` — reconfiguration
    walls AND the per-step compute the allocation earns between them,
    width-weighted on the uneven ``core_pool``.

    Optimizer rows run the knob search twice per workload on the uneven
    :data:`THRPT_MODEL_UNEVEN` pool: once on the classic makespan
    objective (its winner then priced under the model), once with
    ``throughput=`` swapping the makespan term for modeled
    time-to-result, next to the rigid control.  ``diverges`` /
    ``wins`` in the derived column pin the acceptance criterion — the
    two objectives pick different knobs and the time-to-result winner
    is genuinely faster — and the ``gain`` row carries the margin
    itself (makespan-winner ttr minus ttr-winner ttr) so the bench
    drift gate fails if a regression ever collapses it.
    """
    rows = []
    for name in traces:
        sc = get_scenario(name)
        for spec in registered_strategies():
            if spec.homogeneous_only and sc.heterogeneous:
                continue
            recs = run_scenario_vectorized(
                sc, engine=sc.default_engine(strategy=spec.key),
                throughput=THRPT_MODEL)
            rows.append({
                "table": "strategy", "scenario": name, "strategy": spec.key,
                "time_to_result_s": round(time_to_result(
                    recs, sc, THRPT_MODEL), 6),
                "makespan_s": round(sum(r.est_wall_s for r in recs), 6),
                "accrued_s": round(sum(r.time_to_result_s for r in recs), 6),
                "events": len(recs),
                "uneven_pool": bool(sc.core_pool),
            })
    for wl, trace in sorted(WORKLOAD_TRACES.items()):
        kgrid = grid if grid is not None else KNOB_GRID
        mk = optimize_schedule(trace, grid=kgrid, n_random=n_random,
                               seed=seed)
        tt = optimize_schedule(trace, grid=kgrid, n_random=n_random,
                               seed=seed, throughput=THRPT_MODEL_UNEVEN)
        mk_out = evaluate_schedule(trace, mk.best.knobs,
                                   throughput=THRPT_MODEL_UNEVEN)
        diverges = mk.best.knobs != tt.best.knobs
        wins = tt.best.time_to_result_s < mk_out.time_to_result_s

        def fmt(knobs) -> str:
            if knobs is None:
                return "-"
            return (f"t{knobs.backfill_threshold}"
                    f"-p{knobs.preempt_priority}"
                    f"-q{knobs.placement_quantum}")

        for objective, out in (("rigid", tt.baseline),
                               ("makespan-objective", mk_out),
                               ("ttr-objective", tt.best)):
            rows.append({
                "table": "optimizer", "workload": wl, "objective": objective,
                "time_to_result_s": round(out.time_to_result_s, 6),
                "makespan_s": round(out.makespan_s, 6),
                "mean_queue_s": round(out.mean_queue_s, 6),
                "utilization": round(out.utilization, 4),
                "knobs": fmt(out.knobs),
                "diverges": diverges, "wins": wins,
            })
        rows.append({
            "table": "optimizer", "workload": wl, "objective": "gain",
            "time_to_result_s": round(
                mk_out.time_to_result_s - tt.best.time_to_result_s, 6),
            "makespan_s": 0.0, "mean_queue_s": 0.0, "utilization": 0.0,
            "knobs": f"{fmt(mk.best.knobs)}->{fmt(tt.best.knobs)}",
            "diverges": diverges, "wins": wins,
        })
    return rows


# ------------------------------------------- stage-3 redistribution tables --
REDIST_ARCHS = ("xlstm_125m", "stablelm_3b", "gemma2_9b")
REDIST_RESIZES = ((1, 4), (1, 8), (4, 8), (8, 4), (8, 1))


def table_redistribution(archs: tuple[str, ...] = REDIST_ARCHS) -> list[dict]:
    """Expansion/shrink wall time once stage-3 prices real pytree sizes.

    For each model config and (I -> N) resize, charge the timeline with
    both analytic bytes models (replicated = grow-heavy, fsdp =
    every-resize-heavy).  The redistribution share of est_wall is the
    paper's motivation for overlap: it dominates once spawning is
    parallel.
    """
    rows = []
    for arch in archs:
        pb = param_bytes_for_arch(arch)
        for model_name, bytes_model in (
            ("replicated", replicated_bytes_model(pb)),
            ("fsdp", fsdp_bytes_model(pb)),
        ):
            engine = ReconfigEngine(cost_model=MN5, bytes_model=bytes_model)
            for i, n in REDIST_RESIZES:
                if n > i:
                    kind = "expand"
                    tl = engine.timeline(engine.plan_expand(i, n, 1))
                else:
                    kind = "shrink"
                    # TS shrink of the doomed single-rank worlds
                    tl = shrink_timeline(
                        ShrinkKind.TS, MN5, ns=i, nt=n,
                        doomed_world_sizes=[1] * (i - n),
                        bytes_total=engine.redistribution_bytes(i, n),
                    )
                rows.append({
                    "arch": arch, "bytes_model": model_name, "kind": kind,
                    "I": i, "N": n, "time_s": round(tl.total, 6),
                    "bytes_moved": tl.bytes_moved,
                    "redist_share": round(
                        tl.span(Stage.REDISTRIBUTION) / tl.total, 3
                    ) if tl.total else 0.0,
                })
    return rows


OVERLAP_FRACTIONS = (0.0, 0.5, 1.0)
CONTENTIONS = (1.0, 1.25, 1.5)


def overlap_sweep(arch: str = "stablelm_3b") -> list[dict]:
    """ASYNC downtime under partial redistribution overlap x contention.

    One expansion (1 -> 8 ranks) moving ``arch``'s pytree; sweep how much
    of the redistribution phase hides under compute and how hard the
    hidden portion contends with it.  fraction=0 or contention=2 degrade
    to the synchronous stall; fraction=1, contention=1 is MaM's binary
    hiding applied to stage 3.
    """
    pb = param_bytes_for_arch(arch)
    rows = []
    for f in OVERLAP_FRACTIONS:
        for c in CONTENTIONS:
            cm = MN5.with_overlap(redistribution=f, contention=c)
            engine = ReconfigEngine(
                cost_model=cm, asynchronous=True,
                bytes_model=replicated_bytes_model(pb),
            )
            outcome = engine.execute(engine.plan_expand(1, 8, 1))
            rows.append({
                "arch": arch, "overlap_fraction": f, "contention": c,
                "est_wall_s": round(outcome.total_s, 6),
                "downtime_s": round(outcome.downtime_s, 6),
                "hidden_share": round(
                    1.0 - outcome.downtime_s / outcome.total_s, 3),
                "bytes_moved": outcome.bytes_moved,
            })
    return rows


# --------------------------------------------- simulator throughput scale --
SCALE_SIZES = (1_000, 10_000, 100_000)
SCALE_OBJECT_CAP = 1_000
SCALE_MC_NODES = 10_000
SCALE_MC_REPLICAS = 1_000
SCALE_MC_DECISIONS = 25


def table_scale(sizes: tuple[int, ...] = SCALE_SIZES,
                object_cap: int = SCALE_OBJECT_CAP,
                mc_nodes: int = SCALE_MC_NODES,
                mc_replicas: int = SCALE_MC_REPLICAS) -> list[dict]:
    """Measured simulator throughput: object vs vectorized charging.

    For each churn-trace size, time the vectorized executor
    (:func:`run_scenario_vectorized`, memoizing transition cache) and —
    up to ``object_cap`` events, because it is the slow side being
    measured — the object executor (:func:`run_scenario_sim`, which
    replays live cluster mutations per event).  The object path's
    per-event cost is size-independent (same 8-node pool, same
    transition mix), so its ``object_cap`` rate stands in for the larger
    traces and ``speedup_vs_object`` stays meaningful at 100k events
    without a minutes-long object run.  The final row times a
    1000-replica seeded :class:`ChurnPolicy` Monte-Carlo sweep over a
    10k-node pod through one shared transition cache.

    Unlike every other table these rows are MEASURED wall time, not
    simulated cost: they are machine-dependent, live in the ``scale``
    section of ``run.py --json`` (never in the drift-compared ``rows``),
    and are gated by thresholds (min speedup, max MC seconds) in
    ``scripts/check_bench.py``.
    """
    def best_of(fn, repeats: int):
        """(min wall seconds, last result) — best-of-N damps GC pauses
        and scheduler noise, the usual throughput-measurement hygiene."""
        best, out = float("inf"), None
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best, out

    rows: list[dict] = []
    object_rate = 0.0
    for n in sizes:
        sc = churn_trace(name=f"scale-churn-{n}", decisions=n)
        measured = n <= object_cap
        if measured:
            obj_s, obj_recs = best_of(lambda: run_scenario_sim(sc), 2)
            object_rate = len(obj_recs) / obj_s
        vec_s, recs = best_of(lambda: run_scenario_vectorized(sc), 3)
        vec_rate = len(recs) / vec_s if vec_s > 0 else 0.0
        rows.append({
            "table": "scale",
            "events": len(recs),
            "object_events_per_s": round(object_rate),
            "object_measured": measured,
            "vectorized_events_per_s": round(vec_rate),
            "vectorized_wall_s": round(vec_s, 4),
            "speedup_vs_object": round(vec_rate / object_rate, 1)
            if object_rate else 0.0,
        })
    cluster = RmsClusterState(
        total_nodes=mc_nodes,
        jobs=(JobSpec("train", min_nodes=1, max_nodes=mc_nodes),),
    )
    t0 = time.perf_counter()
    sweep = monte_carlo_sweep(
        ChurnPolicy(decisions=SCALE_MC_DECISIONS), mc_replicas,
        cluster=cluster)
    mc_s = time.perf_counter() - t0
    rows.append({
        "table": "scale-mc",
        "pool_nodes": mc_nodes,
        "replicas": sweep.n_replicas,
        "reconfigs": sweep.reconfigs,
        "cache_hits": sweep.cache_hits,
        "cache_misses": sweep.cache_misses,
        "wall_s": round(mc_s, 3),
        "reconfigs_per_s": round(sweep.reconfigs / mc_s) if mc_s > 0 else 0,
        "makespan_mean_s": round(sum(sweep.makespans) / len(sweep.makespans), 6),
        "downtime_mean_s": round(sum(sweep.downtimes) / len(sweep.downtimes), 6),
    })
    return rows


# ------------------------------------------------------- envelope summary --
def paper_envelopes(mn5_nodes: list[int] = MN5_NODES,
                    nasp_nodes: list[int] = NASP_NODES) -> list[dict]:
    """The four headline numbers the paper reports, from our simulator."""
    fig4a = fig4a_homogeneous_expansion(mn5_nodes)
    fig6 = fig6_heterogeneous(nasp_nodes)
    worst_m = max(r["vs_merge"] for r in fig4a
                  if r["method"] in ("M+hypercube", "M+diffusive"))
    worst_b = max(r["vs_merge"] for r in fig4a
                  if r["method"].startswith("B+"))
    min_ts_mn5 = min(r["speedup_ts"] for r in fig4b_homogeneous_shrink(mn5_nodes)
                     if r["method"] != "M+TS")
    worst_m_nasp = max(r["vs_merge"] for r in fig6
                       if r.get("method") == "M+diffusive")
    min_ts_nasp = min(r["speedup_ts"] for r in fig6
                      if r.get("figure") == "6b" and r["method"] != "M+TS")
    return [
        {"metric": "parallel Merge expansion overhead (MN5)",
         "ours": round(worst_m, 3), "paper": "<= 1.13x"},
        {"metric": "parallel Baseline expansion overhead (MN5)",
         "ours": round(worst_b, 3), "paper": "up to 1.73x"},
        {"metric": "TS shrink speedup (MN5)",
         "ours": round(min_ts_mn5, 0), "paper": ">= 1387x"},
        {"metric": "diffusive Merge expansion overhead (NASP)",
         "ours": round(worst_m_nasp, 3), "paper": "<= 1.25x"},
        {"metric": "TS shrink speedup (NASP)",
         "ours": round(min_ts_nasp, 0), "paper": ">= 20x"},
    ]
