"""One benchmark per paper table/figure (§5), on the calibrated simulator.

Figure 4a  — homogeneous expansion times (MN5, 112-core nodes)
Figure 4b  — homogeneous shrink times (TS vs B-based)
Figure 5   — preferred-method grid
Figure 6a/b — heterogeneous expansion/shrink (NASP, 20/32-core nodes)
Table 2    — iterative diffusive worked example
Figure 1 / Eq. 3 — hypercube round counts
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

from repro.core import (
    Method,
    ShrinkKind,
    Strategy,
    plan_diffusive,
    plan_hypercube,
    plan_sequential,
)
from repro.malleability import MN5, NASP, simulate_expansion, simulate_shrink

MN5_CORES = 112
MN5_NODES = [1, 2, 4, 8, 16, 24, 32]
NASP_NODES = [1, 2, 4, 6, 8, 10, 12, 14, 16]


def nasp_alloc(n: int) -> list[int]:
    """Balanced heterogeneous allocation: alternating 20/32-core nodes
    (one node -> the 20-core type, per §5.3)."""
    return [20 if i % 2 == 0 else 32 for i in range(n)]


def _running(alloc: list[int], ns: int) -> list[int]:
    out, rem = [], ns
    for a in alloc:
        take = min(a, rem)
        out.append(take)
        rem -= take
    return out


# ------------------------------------------------------ Fig 4a: expansion --
def fig4a_homogeneous_expansion() -> list[dict]:
    rows = []
    for i, n in itertools.combinations(MN5_NODES, 2):
        ns, nt = i * MN5_CORES, n * MN5_CORES
        variants = {
            "M": plan_sequential(ns, nt, [MN5_CORES] * n, Method.MERGE),
            "M+hypercube": plan_hypercube(ns, nt, MN5_CORES, Method.MERGE),
            "M+diffusive": plan_diffusive(
                [MN5_CORES] * n, _running([MN5_CORES] * n, ns), Method.MERGE
            ),
            "B+hypercube": plan_hypercube(ns, nt, MN5_CORES, Method.BASELINE),
            "B+diffusive": plan_diffusive(
                [MN5_CORES] * n, _running([MN5_CORES] * n, ns), Method.BASELINE
            ),
        }
        base = simulate_expansion(variants["M"], MN5).total
        for name, plan in variants.items():
            t = simulate_expansion(plan, MN5).total
            rows.append({
                "figure": "4a", "I": i, "N": n, "method": name,
                "time_s": round(t, 4), "vs_merge": round(t / base, 3),
            })
    return rows


# -------------------------------------------------------- Fig 4b: shrink --
def fig4b_homogeneous_shrink() -> list[dict]:
    rows = []
    for n, i in itertools.combinations(MN5_NODES, 2):  # i -> n, i > n
        ns, nt = i * MN5_CORES, n * MN5_CORES
        ts = simulate_shrink(
            ShrinkKind.TS, MN5, ns=ns, nt=nt,
            doomed_world_sizes=[MN5_CORES] * (i - n),
        ).total
        for name, method in [("B+hypercube", Method.BASELINE)]:
            rp = plan_hypercube(ns, nt, MN5_CORES, method)
            ss = simulate_shrink(ShrinkKind.SS, MN5, ns=ns, nt=nt, respawn_plan=rp).total
            rows.append({
                "figure": "4b", "I": i, "N": n, "method": name,
                "time_s": round(ss, 4), "speedup_ts": round(ss / ts, 1),
            })
        rows.append({
            "figure": "4b", "I": i, "N": n, "method": "M+TS",
            "time_s": round(ts, 6), "speedup_ts": 1.0,
        })
    return rows


# ------------------------------------------------ Fig 5: preferred method --
def fig5_preferred_grid() -> list[dict]:
    """Best method per (I, N) cell: expansion upper triangle, shrink lower."""
    rows = []
    for i in MN5_NODES:
        for n in MN5_NODES:
            if i == n:
                continue
            if n > i:   # expansion
                cand = {}
                ns, nt = i * MN5_CORES, n * MN5_CORES
                cand["M"] = simulate_expansion(
                    plan_sequential(ns, nt, [MN5_CORES] * n, Method.MERGE), MN5).total
                cand["M+par"] = simulate_expansion(
                    plan_hypercube(ns, nt, MN5_CORES, Method.MERGE), MN5).total
                cand["B+par"] = simulate_expansion(
                    plan_hypercube(ns, nt, MN5_CORES, Method.BASELINE), MN5).total
            else:       # shrink
                ns, nt = i * MN5_CORES, n * MN5_CORES
                cand = {
                    "M+TS": simulate_shrink(
                        ShrinkKind.TS, MN5, ns=ns, nt=nt,
                        doomed_world_sizes=[MN5_CORES] * (i - n)).total,
                    "B+par": simulate_shrink(
                        ShrinkKind.SS, MN5, ns=ns, nt=nt,
                        respawn_plan=plan_hypercube(ns, nt, MN5_CORES, Method.BASELINE),
                    ).total,
                }
            best = min(cand, key=cand.get)
            rows.append({"figure": "5", "I": i, "N": n, "best": best,
                         "time_s": round(cand[best], 5)})
    return rows


# --------------------------------------- Fig 6: heterogeneous (diffusive) --
def fig6_heterogeneous() -> list[dict]:
    rows = []
    for i, n in itertools.combinations(NASP_NODES, 2):
        alloc = nasp_alloc(n)
        ns, nt = sum(nasp_alloc(i)), sum(alloc)
        r = _running(alloc, ns)
        base = simulate_expansion(
            plan_sequential(ns, nt, alloc, Method.MERGE), NASP).total
        for name, plan in {
            "M": plan_sequential(ns, nt, alloc, Method.MERGE),
            "M+diffusive": plan_diffusive(alloc, r, Method.MERGE),
            "B+diffusive": plan_diffusive(alloc, r, Method.BASELINE),
        }.items():
            t = simulate_expansion(plan, NASP).total
            rows.append({"figure": "6a", "I": i, "N": n, "method": name,
                         "time_s": round(t, 4), "vs_merge": round(t / base, 3)})
    for n, i in itertools.combinations(NASP_NODES, 2):
        alloc_t = nasp_alloc(n)
        ns, nt = sum(nasp_alloc(i)), sum(alloc_t)
        doomed = nasp_alloc(i)[n:]
        ts = simulate_shrink(ShrinkKind.TS, NASP, ns=ns, nt=nt,
                             doomed_world_sizes=doomed).total
        rp = plan_diffusive(alloc_t, [0] * len(alloc_t) or None, Method.BASELINE) \
            if False else plan_diffusive(alloc_t, _running(alloc_t, min(ns, nt)), Method.BASELINE)
        ss = simulate_shrink(ShrinkKind.SS, NASP, ns=ns, nt=nt, respawn_plan=rp).total
        rows.append({"figure": "6b", "I": i, "N": n, "method": "B+diffusive",
                     "time_s": round(ss, 4), "speedup_ts": round(ss / ts, 1)})
        rows.append({"figure": "6b", "I": i, "N": n, "method": "M+TS",
                     "time_s": round(ts, 6), "speedup_ts": 1.0})
    return rows


# ------------------------------------------------- Table 2 + Eq. 3 traces --
def table2_trace() -> list[dict]:
    A = [4, 2, 8, 12, 3, 3, 4, 4, 6, 3]
    R = [2, 0, 0, 0, 0, 0, 0, 0, 0, 0]
    plan = plan_diffusive(A, R, Method.MERGE)
    return [
        {"figure": "T2", "s": tr.s, "t": tr.t, "g": tr.g, "lambda": tr.lam,
         "T": tr.T, "G": tr.G}
        for tr in plan.trace
    ]


def fig1_hypercube_rounds() -> list[dict]:
    rows = []
    for cores, i, n in [(1, 1, 8), (20, 1, 21), (20, 1, 441), (112, 1, 32),
                        (112, 2, 32), (112, 16, 32)]:
        plan = plan_hypercube(i * cores, n * cores, cores, Method.MERGE)
        rows.append({"figure": "1/Eq3", "C": cores, "I": i, "N": n,
                     "rounds": plan.steps, "groups": len(plan.groups)})
    return rows


# ------------------------------------------------------- envelope summary --
def paper_envelopes() -> list[dict]:
    """The four headline numbers the paper reports, from our simulator."""
    worst_m = max(r["vs_merge"] for r in fig4a_homogeneous_expansion()
                  if r["method"] in ("M+hypercube", "M+diffusive"))
    worst_b = max(r["vs_merge"] for r in fig4a_homogeneous_expansion()
                  if r["method"].startswith("B+"))
    min_ts_mn5 = min(r["speedup_ts"] for r in fig4b_homogeneous_shrink()
                     if r["method"] != "M+TS")
    worst_m_nasp = max(r["vs_merge"] for r in fig6_heterogeneous()
                       if r.get("method") == "M+diffusive")
    min_ts_nasp = min(r["speedup_ts"] for r in fig6_heterogeneous()
                      if r.get("figure") == "6b" and r["method"] != "M+TS")
    return [
        {"metric": "parallel Merge expansion overhead (MN5)",
         "ours": round(worst_m, 3), "paper": "<= 1.13x"},
        {"metric": "parallel Baseline expansion overhead (MN5)",
         "ours": round(worst_b, 3), "paper": "up to 1.73x"},
        {"metric": "TS shrink speedup (MN5)",
         "ours": round(min_ts_mn5, 0), "paper": ">= 1387x"},
        {"metric": "diffusive Merge expansion overhead (NASP)",
         "ours": round(worst_m_nasp, 3), "paper": "<= 1.25x"},
        {"metric": "TS shrink speedup (NASP)",
         "ours": round(min_ts_nasp, 0), "paper": ">= 20x"},
    ]
