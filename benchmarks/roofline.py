"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
  memory term     = HLO_bytes / (chips x 819 GB/s)
  collective term = collective_bytes / (chips x 50 GB/s per link)

FLOPs/bytes from the while-aware HLO analysis are already *per device*
(post-SPMD module), so the per-chip terms drop the chips division.
MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (+ attention
cache reads) for decode; the ratio MODEL_FLOPS/HLO_FLOPs measures how
much compiled compute is useful (remat/dispatch waste shows up here).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, arch_config

HW = {"peak_flops_bf16": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}


def model_flops_per_device(rec: dict) -> float:
    """Analytic useful FLOPs for the cell, per chip."""
    cfg = arch_config(rec["arch"])
    shape = next(s for s in SHAPES if s.name == rec["shape"])
    n_active = cfg.active_param_count()
    chips = rec["n_chips"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence + attention over the cache
    tokens = shape.global_batch
    base = 2.0 * n_active * tokens
    # attention cache read: 2 (QK) + 2 (PV) flops per cached element pair
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        attn_layers = cfg.n_layers
    elif cfg.family == "hybrid":
        attn_layers = cfg.n_layers // max(cfg.attn_every, 1)
    else:
        attn_layers = 0
    base += 4.0 * tokens * attn_layers * cfg.n_heads * cfg.hd * shape.seq_len
    return base / chips


def roofline_terms(rec: dict) -> dict:
    pd = rec["per_device"]
    flops = pd["flops"]
    hbm_bytes = max(pd.get("dot_bytes", 0.0), pd.get("xla_bytes_accessed_raw", 0.0))
    coll = rec["collectives"]["total_bytes"]
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = hbm_bytes / HW["hbm_bw"]
    t_coll = coll / HW["ici_bw"]
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops_per_device(rec)
    bound = max(t_compute, t_memory, t_coll)
    # roofline fraction: useful-compute time / modeled step time
    frac = (mf / HW["peak_flops_bf16"]) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "chips": rec["n_chips"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_dev": mf,
        "hlo_flops_dev": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": frac,
        "peak_hbm_gb": rec["per_device"]["peak_hbm_est"] / 1e9,
    }


def load_records(dir_: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(dir_: str = "results/dryrun", mesh: str = "single") -> list[dict]:
    out = []
    for rec in load_records(dir_):
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        out.append(roofline_terms(rec))
    return out


def what_would_help(row: dict) -> str:
    if row["dominant"] == "compute":
        if row["useful_ratio"] < 0.5:
            return "cut recompute/dispatch waste (remat policy, MoE capacity)"
        return "near compute roofline; only kernel-level fusion is left"
    if row["dominant"] == "memory":
        return "fuse/duplicate-elimination: flash-attention kernel, smaller working set"
    return "reduce collective volume: resharded layout, fewer all-gathers, overlap"
